"""Deliverable (f): per-assigned-architecture smoke tests — a REDUCED config
of the same family runs one forward + one train step on CPU, asserting
output shapes and no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch.train import reduced_config
from repro.models import model_zoo
from repro.training import make_train_state, make_train_step

ARCHS = list_archs()
B, T = 2, 64


def _reduced(arch: str):
    spec = get_arch(arch)
    return reduced_config(spec.model, "smoke")


def test_all_ten_archs_registered():
    assert sorted(ARCHS) == sorted([
        "rwkv6-7b", "qwen2-moe-a2.7b", "qwen3-moe-235b-a22b", "minicpm-2b",
        "llama3.2-1b", "h2o-danube-3-4b", "mistral-nemo-12b",
        "jamba-1.5-large-398b", "whisper-small", "internvl2-2b"])


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config fields are literature-exact per the assignment."""
    cfg = get_arch(arch).model
    expect = {
        "rwkv6-7b": (32, 4096, 14336, 65536),
        "qwen2-moe-a2.7b": (24, 2048, 1408, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 1536, 151936),
        "minicpm-2b": (40, 2304, 5760, 122753),
        "llama3.2-1b": (16, 2048, 8192, 128256),
        "h2o-danube-3-4b": (24, 3840, 10240, 32000),
        "mistral-nemo-12b": (40, 5120, 14336, 131072),
        "jamba-1.5-large-398b": (72, 8192, 24576, 65536),
        "whisper-small": (12, 768, 3072, 51865),
        "internvl2-2b": (24, 2048, 8192, 92553),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expect


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = _reduced(arch)
    rng = jax.random.PRNGKey(0)
    model = model_zoo.build_model(cfg, max_seq=T)
    params = model.init(rng)

    from repro.data.synthetic import synthetic_batch
    shape = ShapeConfig("smoke", T, B, "train")
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, shape, 0).items()}

    loss_fn = model_zoo.make_loss_fn(model)
    loss, metrics = loss_fn(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0

    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1, decay_steps=10)
    state = make_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    state, m2 = step(state, batch)
    assert jnp.isfinite(m2["loss"]), arch
    assert jnp.isfinite(m2["grad_norm"]), arch
    assert int(state.step) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), params, state.params), 0.0)
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ["rwkv6-7b", "jamba-1.5-large-398b",
                                  "llama3.2-1b", "whisper-small",
                                  "internvl2-2b", "qwen2-moe-a2.7b"])
def test_smoke_serve_step(arch):
    """One prefill + one decode step at reduced config."""
    cfg = _reduced(arch)
    rng = jax.random.PRNGKey(1)
    model = model_zoo.build_model(cfg, max_seq=T + 8)
    params = model.init(rng)
    n_prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
    cache = model.init_cache(B, T + n_prefix + 8)
    tok = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    last, cache = model.prefill(params, tok, cache, **kw)
    assert last.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(last).all()), arch
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    logits, cache = model.decode_step(params, nxt, cache,
                                      jnp.int32(T + n_prefix))
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_assignments(arch):
    """Every arch has its 4 shape cells; long_500k runnable only for
    sub-quadratic families (skip recorded for the rest)."""
    spec = get_arch(arch)
    names = [s.name for s in spec.shapes]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    runnable = {s.name for s in spec.runnable_shapes()}
    if arch in ("rwkv6-7b", "jamba-1.5-large-398b"):
        assert "long_500k" in runnable
    else:
        assert "long_500k" not in runnable
        assert "long_500k" in spec.skip_shapes
