"""Unit tests for the dry-run / roofline analysis machinery (HLO parsing,
resident-bytes accounting, rule merging incl. serve_rules, analytic
MODEL_FLOPS sanity)."""
import jax
import numpy as np
import pytest

# These imports must not initialize 512 devices — dryrun sets XLA_FLAGS at
# module import, but the device count only locks on first backend use, and
# these tests only exercise pure helpers.
from repro.launch.dryrun import (_line_result_bytes, parse_collectives,
                                 make_rules)
from repro.configs import get_arch, get_shape
from repro.configs.base import ModelConfig, ShapeConfig


def test_line_result_bytes_simple():
    line = "%add.1 = f32[16,128]{1,0} add(%a, %b)"
    assert _line_result_bytes(line) == 16 * 128 * 4
    line2 = "%c = bf16[8]{0} copy(%x)"
    assert _line_result_bytes(line2) == 16
    assert _line_result_bytes("ROOT %t = tuple(...)") == 0


def test_line_result_bytes_tuple_shapes():
    line = ("%ar = (f32[4,4]{1,0}, bf16[2]{0}) all-reduce(%p0, %p1), "
            "replica_groups={}")
    assert _line_result_bytes(line) == 4 * 4 * 4 + 2 * 2


def test_parse_collectives_counts_and_bytes():
    hlo = """
  %x = f32[4] parameter(0)
  %ag = f32[64,4]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[16]{0} all-reduce(%y), to_apply=%sum
  %ar2 = f32[16]{0} all-reduce(%z), to_apply=%sum
  %rs = bf16[8]{0} reduce-scatter(%w), dimensions={0}
  %cp = f32[2]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %dot = f32[99] dot(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 64 * 4 * 4
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 2 * 16 * 4
    assert out["reduce-scatter"]["bytes"] == 8 * 2
    assert out["collective-permute"]["count"] == 1
    assert "dot" not in out


def test_parse_collectives_async_start_variant():
    hlo = "%ags = f32[32]{0} all-gather-start(%x), dimensions={0}"
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)


def test_make_rules_merges_serve_rules_only_for_serving():
    spec = get_arch("jamba-1.5-large-398b")
    mesh = FakeMesh({"data": 16, "model": 16})
    train_shape = get_shape(spec, "train_4k")
    dec_shape = get_shape(spec, "decode_32k")
    r_train = make_rules(spec, train_shape, mesh)
    r_dec = make_rules(spec, dec_shape, mesh)
    assert r_train.rules["mlp"] == ("model", "data")   # training: 256-way
    assert r_dec.rules["mlp"] == ("model",)            # serving: plain TP


def test_shape_overrides_beat_serve_rules():
    spec = get_arch("jamba-1.5-large-398b")
    mesh = FakeMesh({"data": 16, "model": 16})
    long_shape = get_shape(spec, "long_500k")
    r = make_rules(spec, long_shape, mesh)
    assert r.rules["cache_seq"] == ("data",)   # LONG_500K shape override


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS sanity (no device allocation)
# ---------------------------------------------------------------------------


def test_analytic_flops_scales_linearly_with_tokens():
    from repro.launch.roofline import analytic_model_flops
    cfg = get_arch("llama3.2-1b").model
    s1 = ShapeConfig("a", 1024, 8, "train")
    s2 = ShapeConfig("b", 1024, 16, "train")
    f1 = analytic_model_flops(cfg, s1)
    f2 = analytic_model_flops(cfg, s2)
    assert f2 == pytest.approx(2 * f1, rel=1e-6)


def test_analytic_flops_train_is_3x_prefill():
    from repro.launch.roofline import analytic_model_flops
    cfg = get_arch("mistral-nemo-12b").model
    tr = analytic_model_flops(cfg, ShapeConfig("a", 2048, 8, "train"))
    pf = analytic_model_flops(cfg, ShapeConfig("b", 2048, 8, "prefill"))
    assert tr == pytest.approx(3 * pf, rel=1e-6)


def test_analytic_decode_flops_much_smaller_than_prefill():
    from repro.launch.roofline import analytic_model_flops
    for arch in ("rwkv6-7b", "whisper-small", "jamba-1.5-large-398b"):
        cfg = get_arch(arch).model
        pf = analytic_model_flops(cfg, ShapeConfig("b", 4096, 8, "prefill"))
        de = analytic_model_flops(cfg, ShapeConfig("c", 4096, 8, "decode"))
        assert de < pf / 100, arch     # one token vs 4096


def test_moe_active_ratio():
    from repro.launch.roofline import _active_params
    dense = get_arch("llama3.2-1b").model
    moe = get_arch("qwen3-moe-235b-a22b").model
    n_act = _active_params(moe)
    # qwen3: ~22B active of 235B total
    assert 1.5e10 < n_act < 3.5e10
    assert _active_params(dense) > 1.0e9
