"""Benchmark-regression gate (CI step after bench-smoke; `make bench-check`).

Compares the ``BENCH_*.json`` reports that ``make bench-smoke`` just wrote
against the committed baselines in ``benchmarks/baselines/`` and fails when
a headline metric regresses beyond tolerance — so a PR that silently
forfeits the fused-dispatch speedup, the host-byte reduction, or the
serving-queue amortization turns CI red instead of rotting until the next
full benchmark run.

Headline metrics are RATIOS measured within one process on one machine
(fused vs sequential, queued vs per-call), so they are comparable across
hosts in a way absolute wall-clock numbers are not; the baselines are
produced by the same ``--quick`` configurations bench-smoke runs.

Checks per metric kind:
  ratio_min — current >= baseline * (1 - tolerance)   (speedups, ratios)
  flag      — a baseline-true boolean must stay true  (parity/residency)
  abs_max   — current <= bound                        (error ceilings)

``--tolerance`` sets the default relative tolerance (0.20); individual
metrics may override it where the quantity is deterministic (byte ratios)
or noisy (thread-scheduling-dependent speedups).

Usage:  python tools/check_bench.py [--tolerance 0.2]
                                    [--baseline-dir benchmarks/baselines]
                                    [--bench-dir .]
Exit status: number of failing metrics (0 = clean).  A missing baseline or
report is a failure — the gate must never pass vacuously.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# metric registry: file -> ((path, kind, override), ...)
#   path      dotted key into the report json
#   kind      'ratio_min' | 'flag' | 'abs_max'
#   override  per-metric tolerance (ratio_min) or bound (abs_max);
#             None = use --tolerance / the flag semantics
HEADLINE = {
    "BENCH_committee_uq.json": (
        ("speedup_wallclock", "ratio_min", None),
        # shape-determined byte accounting is deterministic: any change is
        # a real transfer regression, not noise
        ("bytes_reduction_factor", "ratio_min", 0.02),
        ("buckets_compile_once", "flag", None),
    ),
    "BENCH_budget_controller.json": (
        # the controller's own acceptance: settled realized rate within
        # 10% of the configured oracle budget
        ("budget_rate_rel_error", "abs_max", 0.10),
        ("state_device_resident", "flag", None),
        ("uq_bytes_identical_to_default", "flag", None),
    ),
    "BENCH_serving_queue.json": (
        # thread-scheduling dependent -> wider band, but the acceptance
        # floor (>= 3x) is absolute: never pass below it
        ("queued_vs_percall_speedup", "ratio_min", 0.40),
        ("queue_reuses_engine_buckets", "flag", None),
    ),
    "BENCH_fault_recovery.json": (
        # labeled-throughput retention under the standard fault plan is
        # scheduling-noisy around 1.0 -> wide band; the ISSUE acceptance
        # floor (>= 0.70 of fault-free throughput) is absolute
        ("throughput_retention", "ratio_min", 0.30),
        # the chaos campaign must end on its own window, never on a
        # fault-escalated StopToken
        ("completed_without_stop", "flag", None),
    ),
    "BENCH_committee_train.json": (
        # dispatch-count dominated, but still wall-clock -> wide band;
        # the >= 3x acceptance floor below is absolute
        ("speedup_fused_retrain", "ratio_min", 0.40),
        # trainer -> engine weight handoff must stay device-to-device
        ("refresh_device_zero_host_bytes", "flag", None),
    ),
    "BENCH_committee_memory.json": (
        # byte ratios are shape-determined (eval_shape-exact accounting):
        # the ISSUE's K=64 memory-diet gates are absolute bounds
        ("opt_bytes_ratio_int8_vs_fp32_k64", "abs_max", 0.40),
        # per-member-normalized step time is wall-clock -> the 1.5x ISSUE
        # gate already carries slack; keep it absolute
        ("steptime_per_member_ratio_int8_k64_vs_fp32_k8", "abs_max", 1.5),
        # K=64 must score through BOTH fused UQ backends via the zero-copy
        # device handoff
        ("k64_scores_fused_all_backends", "flag", None),
        # dryrun.committee_state_bytes must stay exact vs measured buffers
        ("estimate_matches_measured", "flag", None),
        ("all_losses_finite", "flag", None),
    ),
    "BENCH_serving_tier.json": (
        # cache-hit-rate and scheduling dependent -> wide band; the ISSUE
        # acceptance floor (tier serves >= the PR-4 queue) is absolute
        ("requests_per_s_ratio_vs_pr4", "ratio_min", 0.50),
        # worst-tenant >= 0.5x best-tenant under Zipf demand (DRR bound)
        ("fairness_bound_ok", "flag", None),
        # the p99 controller must hold its target within 25%
        ("p99_target_rel_error", "abs_max", 0.25),
    ),
    "BENCH_mesh_scaleout.json": (
        # fused 8-device-mesh scoring vs the seed's sequential per-member
        # path — wall-clock on emulated (time-sliced) devices -> wide
        # band; the >= 2x acceptance floor below is absolute
        ("speedup_mesh8_vs_legacy_1dev", "ratio_min", 0.40),
        # weak scaling on emulated devices is dispatch-overhead bound and
        # scheduling-noisy (single-core host time-slices all 8 devices):
        # curve is recorded for real-hardware comparison, gated loosely
        ("weak_scaling.ratio_8dev", "ratio_min", 0.50),
        # bit-identity of every fused path on the (8, 1) mesh vs the
        # unsharded engine — any False is a resharding numerics bug
        ("parity_score", "flag", None),
        ("parity_score_after", "flag", None),
        ("parity_train", "flag", None),
        ("parity_serving", "flag", None),
    ),
    "BENCH_exploration_fleet.json": (
        # python-call-count dominated, but still wall-clock -> wide band;
        # the >= 5x acceptance floor below is absolute
        ("speedup_proposals_per_s", "ratio_min", 0.40),
        # the fleet hot loop must never upload per-iteration bytes —
        # unselected walkers stay on device
        ("fleet_zero_upload_bytes", "flag", None),
    ),
}

# absolute floors that hold regardless of baseline drift
FLOORS = {
    ("BENCH_fault_recovery.json", "throughput_retention"): 0.70,
    ("BENCH_serving_queue.json", "queued_vs_percall_speedup"): 3.0,
    ("BENCH_committee_uq.json", "speedup_wallclock"): 2.0,
    ("BENCH_committee_train.json", "speedup_fused_retrain"): 3.0,
    ("BENCH_exploration_fleet.json", "speedup_proposals_per_s"): 5.0,
    ("BENCH_serving_tier.json", "requests_per_s_ratio_vs_pr4"): 1.0,
    ("BENCH_mesh_scaleout.json", "speedup_mesh8_vs_legacy_1dev"): 2.0,
}


def _get(report: dict, path: str):
    cur = report
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_file(name: str, bench_dir: str, baseline_dir: str,
               tolerance: float) -> int:
    cur_path = os.path.join(bench_dir, name)
    base_path = os.path.join(baseline_dir, name)
    if not os.path.exists(cur_path):
        print(f"  FAIL {name}: report missing (did bench-smoke run?)")
        return 1
    if not os.path.exists(base_path):
        print(f"  FAIL {name}: no committed baseline at "
              f"{os.path.relpath(base_path, REPO)}")
        return 1
    cur_rep = json.load(open(cur_path))
    base_rep = json.load(open(base_path))

    failures = 0
    for path, kind, override in HEADLINE[name]:
        cur = _get(cur_rep, path)
        base = _get(base_rep, path)
        if cur is None or (base is None and kind != "abs_max"):
            print(f"  FAIL {name}:{path}: metric missing "
                  f"(current={cur!r}, baseline={base!r})")
            failures += 1
            continue
        if kind == "flag":
            if bool(base) and not bool(cur):
                print(f"  FAIL {name}:{path}: was true in baseline, "
                      f"now {cur!r}")
                failures += 1
            else:
                print(f"  ok   {name}:{path} = {cur!r}")
        elif kind == "abs_max":
            bound = override if override is not None else float(base)
            if float(cur) > bound:
                print(f"  FAIL {name}:{path}: {float(cur):.4g} exceeds "
                      f"bound {bound:.4g}")
                failures += 1
            else:
                print(f"  ok   {name}:{path} = {float(cur):.4g} "
                      f"(bound {bound:.4g})")
        else:  # ratio_min
            tol = override if override is not None else tolerance
            need = float(base) * (1.0 - tol)
            floor = FLOORS.get((name, path))
            if floor is not None:
                need = max(need, floor)
            if float(cur) < need:
                print(f"  FAIL {name}:{path}: {float(cur):.3g} < required "
                      f"{need:.3g} (baseline {float(base):.3g}, "
                      f"tolerance {tol:.0%}"
                      + (f", floor {floor:g}" if floor is not None else "")
                      + ")")
                failures += 1
            else:
                print(f"  ok   {name}:{path} = {float(cur):.3g} "
                      f"(baseline {float(base):.3g}, required "
                      f">= {need:.3g})")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="default relative regression tolerance (0.20)")
    ap.add_argument("--baseline-dir",
                    default=os.path.join(REPO, "benchmarks", "baselines"))
    ap.add_argument("--bench-dir", default=REPO,
                    help="where bench-smoke wrote the BENCH_*.json reports")
    args = ap.parse_args(argv)

    total = 0
    for name in sorted(HEADLINE):
        print(f"== {name}")
        total += check_file(name, args.bench_dir, args.baseline_dir,
                            args.tolerance)
    print(f"bench check: {'OK' if total == 0 else f'{total} failure(s)'}")
    return min(total, 99)


if __name__ == "__main__":
    sys.exit(main())
