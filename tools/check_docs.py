"""Docs smoke-checker (CI `docs` job; `make docs-check`).

Two guarantees for README.md and docs/*.md:

* every fenced ```python code block actually runs — each block is
  exec'd in its own namespace with src/ importable, so API drift in the
  docs fails CI instead of rotting silently.  Blocks whose first line is
  ``# doc: no-run`` are skipped (illustrative shell-output, pseudo-code).
* every intra-repo markdown link ([text](relative/path)) resolves to an
  existing file, anchors stripped.  http(s) links are not checked.

Usage:  PYTHONPATH=src python tools/check_docs.py [files...]
Exit status: number of failures (0 = clean).
"""
from __future__ import annotations

import glob
import os
import re
import sys
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

BLOCK_RE = re.compile(r"```python[^\n]*\n(.*?)```", re.DOTALL)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def default_files():
    files = [os.path.join(REPO, "README.md")]
    files += sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def check_snippets(path: str) -> int:
    failures = 0
    text = open(path).read()
    for i, m in enumerate(BLOCK_RE.finditer(text), 1):
        code = m.group(1)
        first = code.lstrip().splitlines()[0] if code.strip() else ""
        if first.strip().startswith("# doc: no-run"):
            continue
        line = text[:m.start()].count("\n") + 1
        try:
            exec(compile(code, f"{path}:block{i}", "exec"), {})  # noqa: S102
            print(f"  ok   snippet {i} (line {line})")
        except BaseException:  # noqa: BLE001
            failures += 1
            print(f"  FAIL snippet {i} (line {line}):")
            traceback.print_exc()
    return failures


def check_links(path: str) -> int:
    failures = 0
    base = os.path.dirname(os.path.abspath(path))
    for m in LINK_RE.finditer(open(path).read()):
        target = m.group(1).strip()
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            failures += 1
            print(f"  FAIL broken link: ({target}) -> {resolved}")
    return failures


def main(argv):
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    files = [os.path.abspath(a) for a in argv] or default_files()
    total = 0
    for f in files:
        print(f"== {os.path.relpath(f, REPO)}")
        total += check_snippets(f)
        total += check_links(f)
    print(f"docs check: {'OK' if total == 0 else f'{total} failure(s)'}")
    return min(total, 99)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
