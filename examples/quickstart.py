"""PAL quickstart — the paper's workflow in ~100 lines (photodynamics-style,
§3.1): a committee of MLP potentials drives parallel MD-like generators;
uncertain geometries go to an analytic 'DFT' oracle; the fused committee
trainer continuously refits; weights flow back to the prediction committee.
Patience policy included (§2.2).

Prediction runs on the unified acquisition engine: a ``CommitteeSpec``
hands PAL the per-member forward + stacked params, and the committee
forward, uncertainty statistics, and selection rules execute as ONE fused
device dispatch per exchange iteration (``PALRunConfig.uq_impl``).

Training is the same story: ``loss_fn=`` turns on the shared
``training/committee_trainer.CommitteeTrainer`` — all K members advance in
one vmapped dispatch per step on per-member bootstrap minibatches drawn
from a device-resident replay ring, and refreshed weights hand off to the
engine device-to-device (no hand-rolled retrain loop, no packed host
round trip).

  PYTHONPATH=src python examples/quickstart.py [--timeout 45]
"""
import argparse
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.pal_potential import PALRunConfig, PotentialConfig
from repro.core import PAL, CommitteeSpec, UserGene, UserOracle
from repro.core import committee as cmte
from repro.models import potential as pot

PCFG = PotentialConfig(n_atoms=6, committee_size=4, hidden=(64, 64), n_rbf=24)


class MDGenerator(UserGene):
    """One MD trajectory: Euler steps on committee-mean forces; restarts to
    the last trusted geometry when the controller flags high uncertainty
    past patience (it then receives data_to_gene=None)."""

    def __init__(self, rank, result_dir):
        super().__init__(rank, result_dir)
        rng = np.random.RandomState(rank)
        lattice = np.stack(np.meshgrid([0, 1.3], [0, 1.3], [0, 1.3]),
                           -1).reshape(-1, 3)[:PCFG.n_atoms]
        self.x0 = (lattice + rng.randn(PCFG.n_atoms, 3) * 0.05).astype(
            np.float32)
        self.x = self.x0.copy()
        self.rng = rng
        self.steps = 0
        self.restarts = 0

    def generate_new_data(self, data_to_gene):
        self.steps += 1
        if self.steps > 200_000:        # effectively timeout-bounded
            return True, self.x.reshape(-1)
        if data_to_gene is None and self.steps > 1:
            self.x = self.x0.copy()              # patience exceeded: restart
            self.restarts += 1
        elif data_to_gene is not None:
            forces = np.clip(data_to_gene.reshape(PCFG.n_atoms, 3), -20, 20)
            self.x = self.x + 0.002 * forces \
                + self.rng.randn(*self.x.shape).astype(np.float32) * 0.01
        return False, self.x.reshape(-1).astype(np.float32)


class LJOracle(UserOracle):
    """Analytic Lennard-Jones cluster = the 'DFT' ground truth stand-in."""

    def __init__(self, rank, result_dir):
        super().__init__(rank, result_dir)
        # jit once: unjitted op-by-op dispatch starves behind the busy
        # exchange/training threads on the single host device
        self._ef = jax.jit(pot.lj_energy_forces)

    def run_calc(self, input_for_orcl):
        coords = jnp.asarray(input_for_orcl.reshape(PCFG.n_atoms, 3))
        _, f = self._ef(coords)
        return input_for_orcl, np.asarray(f).reshape(-1).astype(np.float32)


def member_forces(p, flat_batch):                # (n, 3A) -> (n, 3A)
    """ONE committee member's force field over a batch of flat coords —
    the apply_fn of the CommitteeSpec AND the forward inside the loss."""
    def one(flat):
        _, f = pot.energy_forces(p, flat.reshape(PCFG.n_atoms, 3), PCFG)
        return f.reshape(-1)
    return jax.vmap(one)(flat_batch)


def member_force_loss(p, batch):
    """Per-member training loss for the fused committee trainer: MSE on
    oracle forces over the minibatch ``{"x": coords, "y": forces}``."""
    pred = member_forces(p, batch["x"])
    return jnp.mean((pred - batch["y"]) ** 2), {}


def make_committee_spec(n_members: int, seed_offset: int = 0
                        ) -> CommitteeSpec:
    """Fused-engine committee: per-member force field over flat coords."""
    cparams = cmte.stack_members([
        pot.init(PCFG, jax.random.PRNGKey(i + seed_offset))
        for i in range(n_members)])
    return CommitteeSpec(member_forces, cparams)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=float, default=45.0,
                    help="run budget in seconds (CI smoke uses a short one)")
    args = ap.parse_args(argv)
    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(prefix="pal_quickstart_"),
        gene_process=8, orcl_process=4, pred_process=4, ml_process=4,
        retrain_size=16, std_threshold=0.25, patience=5,
        weight_sync_every=1, checkpoint_every=10.0,
        train_steps=400, train_batch=64, train_lr=1e-3)
    pal = PAL(cfg, make_generator=MDGenerator, make_oracle=LJOracle,
              committee=make_committee_spec(PCFG.committee_size),
              loss_fn=member_force_loss)
    print("running PAL (8 MD generators, 4-NN committee, 4 LJ oracles, "
          f"fused acquisition engine uq_impl={cfg.uq_impl}, "
          "fused committee trainer)...")
    token = pal.run(timeout=args.timeout)
    rep = pal.report()
    print(f"stopped by: {token}")
    print(f"exchange iterations : {rep['counters'].get('exchange.iterations')}")
    print(f"labeled by oracle   : {rep['labeled_total']}")
    print(f"retrain rounds      : {rep['counters'].get('train.retrains')}")
    print(f"fused train steps   : {rep['train_fused_steps']}")
    print(f"device weight hands : {rep['device_weight_refreshes']} "
          f"(packed host bytes: {pal.engine.refresh_host_bytes})")
    print(f"generator restarts  : "
          f"{sum(g.restarts for g in pal.generators)}")
    print(f"AL checkpoints      : {pal.checkpointer.saves}")
    assert rep["labeled_total"] > 0 and rep["device_weight_refreshes"] > 0
    assert pal.engine.refresh_host_bytes == 0
    print("OK")


if __name__ == "__main__":
    main()
