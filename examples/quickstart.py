"""PAL quickstart — the paper's workflow in ~100 lines (photodynamics-style,
§3.1): a committee of MLP potentials drives parallel MD-like generators;
uncertain geometries go to an analytic 'DFT' oracle; trainers continuously
refit; weights flow back to the prediction committee. Patience policy
included (§2.2).

Prediction runs on the unified acquisition engine: a ``CommitteeSpec``
hands PAL the per-member forward + stacked params, and the committee
forward, uncertainty statistics, and selection rules execute as ONE fused
device dispatch per exchange iteration (``PALRunConfig.uq_impl``).

  PYTHONPATH=src python examples/quickstart.py [--timeout 45]
"""
import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.pal_potential import PALRunConfig, PotentialConfig
from repro.core import PAL, CommitteeSpec, UserGene, UserModel, UserOracle
from repro.core import committee as cmte
from repro.models import potential as pot

PCFG = PotentialConfig(n_atoms=6, committee_size=4, hidden=(64, 64), n_rbf=24)


class MDGenerator(UserGene):
    """One MD trajectory: Euler steps on committee-mean forces; restarts to
    the last trusted geometry when the controller flags high uncertainty
    past patience (it then receives data_to_gene=None)."""

    def __init__(self, rank, result_dir):
        super().__init__(rank, result_dir)
        rng = np.random.RandomState(rank)
        lattice = np.stack(np.meshgrid([0, 1.3], [0, 1.3], [0, 1.3]),
                           -1).reshape(-1, 3)[:PCFG.n_atoms]
        self.x0 = (lattice + rng.randn(PCFG.n_atoms, 3) * 0.05).astype(
            np.float32)
        self.x = self.x0.copy()
        self.rng = rng
        self.steps = 0
        self.restarts = 0

    def generate_new_data(self, data_to_gene):
        self.steps += 1
        if self.steps > 200_000:        # effectively timeout-bounded
            return True, self.x.reshape(-1)
        if data_to_gene is None and self.steps > 1:
            self.x = self.x0.copy()              # patience exceeded: restart
            self.restarts += 1
        elif data_to_gene is not None:
            forces = np.clip(data_to_gene.reshape(PCFG.n_atoms, 3), -20, 20)
            self.x = self.x + 0.002 * forces \
                + self.rng.randn(*self.x.shape).astype(np.float32) * 0.01
        return False, self.x.reshape(-1).astype(np.float32)


class CommitteePotential(UserModel):
    """Prediction & training kernel: MLP potential committee member."""

    def __init__(self, rank, result_dir, i_device, mode):
        super().__init__(rank, result_dir, i_device, mode)
        self.params = pot.init(PCFG, jax.random.PRNGKey(
            rank + (1000 if mode == "train" else 0)))
        self.x_train, self.y_train = [], []

        def forces(p, flat):
            _, f = pot.energy_forces(p, flat.reshape(PCFG.n_atoms, 3), PCFG)
            return f.reshape(-1)

        self._forces = jax.jit(jax.vmap(forces, in_axes=(None, 0)))

        def loss(p, xs, ys):
            pred = jax.vmap(lambda x: forces(p, x), in_axes=0)(xs)
            return jnp.mean((pred - ys) ** 2)

        self._grad = jax.jit(jax.value_and_grad(loss))

    # --- prediction side -------------------------------------------------
    def predict(self, list_data_to_pred):
        x = jnp.asarray(np.stack(list_data_to_pred))
        return list(np.asarray(self._forces(self.params, x)))

    def update(self, weight_array):
        self.params = cmte.update(self.params, weight_array)

    def get_weight_size(self):
        return cmte.get_weight_size(self.params)

    # --- training side ----------------------------------------------------
    def get_weight(self):
        return cmte.get_weight(self.params)

    def add_trainingset(self, datapoints):
        for inp, lab in datapoints:
            self.x_train.append(inp)
            self.y_train.append(lab)

    BATCH = 64   # fixed minibatch: one jit shape regardless of set growth

    def retrain(self, req_data, max_steps=400):
        rng = np.random.RandomState(len(self.x_train))
        xs_all = np.stack(self.x_train)
        ys_all = np.stack(self.y_train)
        lr = 1e-3
        for _ in range(max_steps):
            idx = rng.randint(0, len(xs_all), size=self.BATCH)
            xs = jnp.asarray(xs_all[idx])
            ys = jnp.asarray(ys_all[idx])
            l, g = self._grad(self.params, xs, ys)
            self.params = jax.tree.map(lambda p, gg: p - lr * gg,
                                       self.params, g)
            if req_data.Test():       # new labeled data arrived -> stop
                break
        return False


class LJOracle(UserOracle):
    """Analytic Lennard-Jones cluster = the 'DFT' ground truth stand-in."""

    def __init__(self, rank, result_dir):
        super().__init__(rank, result_dir)
        # jit once: unjitted op-by-op dispatch starves behind the busy
        # exchange/training threads on the single host device
        self._ef = jax.jit(pot.lj_energy_forces)

    def run_calc(self, input_for_orcl):
        coords = jnp.asarray(input_for_orcl.reshape(PCFG.n_atoms, 3))
        _, f = self._ef(coords)
        return input_for_orcl, np.asarray(f).reshape(-1).astype(np.float32)


def make_committee_spec(n_members: int, seed_offset: int = 0
                        ) -> CommitteeSpec:
    """Fused-engine committee: per-member force field over flat coords."""

    def member_forces(p, flat_batch):            # (n, 3A) -> (n, 3A)
        def one(flat):
            _, f = pot.energy_forces(p, flat.reshape(PCFG.n_atoms, 3), PCFG)
            return f.reshape(-1)
        return jax.vmap(one)(flat_batch)

    cparams = cmte.stack_members([
        pot.init(PCFG, jax.random.PRNGKey(i + seed_offset))
        for i in range(n_members)])
    return CommitteeSpec(member_forces, cparams)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=float, default=45.0,
                    help="run budget in seconds (CI smoke uses a short one)")
    args = ap.parse_args(argv)
    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(prefix="pal_quickstart_"),
        gene_process=8, orcl_process=4, pred_process=4, ml_process=4,
        retrain_size=16, std_threshold=0.25, patience=5,
        weight_sync_every=1, checkpoint_every=10.0)
    pal = PAL(cfg, make_generator=MDGenerator,
              make_model=CommitteePotential, make_oracle=LJOracle,
              committee=make_committee_spec(PCFG.committee_size))
    print("running PAL (8 MD generators, 4-NN committee, 4 LJ oracles, "
          f"fused acquisition engine uq_impl={cfg.uq_impl})...")
    token = pal.run(timeout=args.timeout)
    rep = pal.report()
    print(f"stopped by: {token}")
    print(f"exchange iterations : {rep['counters'].get('exchange.iterations')}")
    print(f"labeled by oracle   : {rep['labeled_total']}")
    print(f"retrain rounds      : {rep['counters'].get('train.retrains')}")
    print(f"weight publishes    : {rep['weight_publishes']}")
    print(f"weight refreshes    : "
          f"{rep['counters'].get('prediction.weight_refreshes')}")
    print(f"generator restarts  : "
          f"{sum(g.restarts for g in pal.generators)}")
    print(f"AL checkpoints      : {pal.checkpointer.saves}")
    assert rep["labeled_total"] > 0 and rep["weight_publishes"] > 0
    print("OK")


if __name__ == "__main__":
    main()
