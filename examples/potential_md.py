"""Faithful PAL reproduction end-to-end: ML-potential active learning for
cluster MD (paper §3.2/§3.3 analog) WITH accuracy validation.

Protocol:
  1. run PAL on LJ-cluster MD with a committee potential until the oracle
     has labeled a target number of geometries;
  2. freeze the committee and evaluate force-MAE on a held-out test set of
     trajectory geometries;
  3. compare against a RANDOM-selection baseline that labels the same
     number of geometries without uncertainty-driven selection — the AL
     advantage the paper's workflow exists to deliver.

``--oracle-budget F`` switches the run to FIXED-BUDGET exploration: the
static std threshold is replaced by the cross-round oracle-rate controller
(core/budget.BudgetRule via ``PALRunConfig.oracle_budget``), which steers
the effective threshold so that a fraction F of each exchange round's MD
proposals goes to the oracle — labeling cost is set up front instead of
drifting with wherever the trajectories wander.  The run prints the
realized oracle rate and the controller's final effective threshold next
to the same MAE validation.

  PYTHONPATH=src python examples/potential_md.py [--budget 160]
  PYTHONPATH=src python examples/potential_md.py --oracle-budget 0.2
"""
import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
sys.path.insert(0, "examples")

from repro.configs.pal_potential import PALRunConfig, PotentialConfig
from repro.core import PAL
from repro.models import potential as pot
from repro.training import CommitteeTrainer
from quickstart import (LJOracle, MDGenerator, PCFG, make_committee_spec,
                        member_force_loss)


def make_test_set(n_traj=16, steps=60, seed=123):
    """Held-out geometries FROM TRAJECTORIES: the domain the generators
    explore is where reliability matters (paper §2.2) — run ground-truth
    LJ dynamics with the same integrator and sample states."""
    rng = np.random.RandomState(seed)
    lattice = np.stack(np.meshgrid([0, 1.3], [0, 1.3], [0, 1.3]),
                       -1).reshape(-1, 3)[:PCFG.n_atoms]
    coords_list = []
    for t in range(n_traj):
        x = lattice + rng.randn(PCFG.n_atoms, 3) * 0.05
        for s in range(steps):
            _, f = pot.lj_energy_forces(jnp.asarray(x))
            f = np.clip(np.asarray(f), -20, 20)
            x = x + 0.002 * f + rng.randn(*x.shape) * 0.01
            if s % 10 == 9:
                coords_list.append(x.copy())
    coords = np.stack(coords_list)
    f = np.stack([np.asarray(pot.lj_energy_forces(jnp.asarray(c))[1])
                  for c in coords])
    # drop exploding-force outliers (atom overlap): they would dominate MAE
    keep = np.abs(f).max(axis=(1, 2)) < 50.0
    return jnp.asarray(coords[keep]), jnp.asarray(f[keep])


def force_mae(cparams, coords, forces_true):
    _, f = pot.batched_committee_energy_forces(cparams, coords, PCFG)
    f_mean = jnp.mean(f, axis=1)
    return float(jnp.mean(jnp.abs(f_mean - forces_true)))


def seed_set(n: int, seed: int = 7):
    """Foundational near-equilibrium dataset (paper §3.3: 'We begin by
    pre-training these ML models on a foundational dataset')."""
    rng = np.random.RandomState(seed)
    lattice = np.stack(np.meshgrid([0, 1.3], [0, 1.3], [0, 1.3]),
                       -1).reshape(-1, 3)[:PCFG.n_atoms]
    coords = np.stack([lattice + rng.randn(PCFG.n_atoms, 3)
                       * rng.uniform(0.02, 0.08) for _ in range(n)])
    labels = np.stack([np.asarray(
        pot.lj_energy_forces(jnp.asarray(c))[1]).reshape(-1)
        for c in coords])
    return list(zip(coords.reshape(n, -1), labels))


SEED_N = 48
WARM_STEPS = 600        # pre-training budget on the foundational set
FINAL_STEPS = 1600      # consolidation budget after the run freezes


def run_al(budget: int, seed: int = 0, oracle_budget: float = 0.0,
           fleet_walkers: int = 16):
    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(prefix="pal_md_"),
        gene_process=8, orcl_process=4, pred_process=4, ml_process=4,
        retrain_size=16, std_threshold=0.3, patience=5,
        weight_sync_every=1,
        train_steps=400, train_batch=64, train_lr=1e-3,
        # device-resident exploration fleet (exploration/fleet.py): N
        # stacked MD walkers advanced + scored + selected in ONE fused
        # dispatch per exchange iteration, with the Euler sampler matching
        # the MDGenerator update (dt=0.002, clip=20, noise=0.01) — trusted
        # restart states come from the MDGenerator lattice initializations.
        # fleet_walkers=0 falls back to the gene_process host generators.
        fleet_walkers=fleet_walkers,
        # >0: cross-round PI control of the effective threshold toward
        # oracle_budget selected-per-round (fixed labeling cost; the
        # static threshold above only seeds the controller)
        oracle_budget=oracle_budget, budget_horizon=16)
    pal = PAL(cfg, make_generator=MDGenerator, make_oracle=LJOracle,
              committee=make_committee_spec(PCFG.committee_size),
              loss_fn=member_force_loss)
    # warm start (paper §3.3: foundational pre-training): the SHARED
    # committee trainer fits all K members on the seed set in one-dispatch
    # steps, then hands weights to the engine device-to-device
    trainer = pal.committee_trainer
    trainer.add_blocks(seed_set(SEED_N))
    trainer.train(steps=WARM_STEPS)
    pal.engine.refresh_from_device(trainer.snapshot_cparams())
    pal.start()
    t0 = time.time()
    while pal.train_buffer.total_labeled < budget and time.time() - t0 < 240:
        time.sleep(0.2)
    pal.shutdown()

    # consolidation: the run froze mid-stream; absorb any blocks still in
    # the trainer channel and finish training the committee on its final
    # set (same step budget as the baseline)
    while pal.trainer_channels[0].poll():
        trainer.add_blocks(pal.trainer_channels[0].recv())
    trainer.train(steps=FINAL_STEPS)
    labeled = pal.train_buffer.total_labeled
    rep = pal.report()
    if oracle_budget > 0:
        # surface what the controller actually did with the budget
        state = pal.engine.state_dict()
        ctrl = state[-1] if state else {}
        rep["budget_controller"] = {
            k: float(np.asarray(v)) for k, v in dict(ctrl).items()}
    return trainer.cparams, labeled, rep


def run_random_baseline(budget: int, seed: int = 1):
    """Same TOTAL label budget (incl. the seed set), random near-equilibrium
    geometries — no uncertainty selection, no exploration guidance.  Runs
    on the SAME shared CommitteeTrainer subsystem as the AL path, so the
    comparison isolates selection, not the optimizer."""
    rng = np.random.RandomState(seed)
    lattice = np.stack(np.meshgrid([0, 1.3], [0, 1.3], [0, 1.3]),
                       -1).reshape(-1, 3)[:PCFG.n_atoms]
    coords = np.stack([lattice + rng.randn(PCFG.n_atoms, 3)
                       * rng.uniform(0.02, 0.08)          # near-eq only:
                       for _ in range(budget)])           # no AL guidance
    labels = np.stack([np.asarray(
        pot.lj_energy_forces(jnp.asarray(c))[1]).reshape(-1)
        for c in coords])
    trainer = CommitteeTrainer(
        member_force_loss,
        make_committee_spec(PCFG.committee_size, seed_offset=1000).cparams,
        batch=64, lr=1e-3, replay_capacity=2048, seed=seed)
    trainer.add_blocks(seed_set(SEED_N))
    trainer.add_blocks(list(zip(coords.reshape(budget, -1), labels)))
    trainer.train(steps=WARM_STEPS + FINAL_STEPS)
    return trainer.cparams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=160,
                    help="total oracle-call budget (run stop criterion)")
    ap.add_argument("--oracle-budget", type=float, default=0.0,
                    help=">0: per-round selected fraction held by the "
                         "cross-round budget controller (fixed-rate "
                         "exploration instead of a static threshold)")
    ap.add_argument("--fleet-walkers", type=int, default=16,
                    help="device-resident exploration-fleet size; 0 runs "
                         "the legacy host-generator path")
    args = ap.parse_args()

    coords_test, forces_test = make_test_set()
    print(f"label budget: {args.budget} oracle calls"
          + (f", controlled at {args.oracle_budget:.0%}/round"
             if args.oracle_budget > 0 else ""))

    cparams_al, labeled, rep = run_al(args.budget,
                                      oracle_budget=args.oracle_budget,
                                      fleet_walkers=args.fleet_walkers)
    mae_al = force_mae(cparams_al, coords_test, forces_test)
    print(f"[PAL active learning] labeled={labeled} "
          f"force MAE={mae_al:.4f}")
    if "fleet" in rep:
        fl = rep["fleet"]
        print(f"[exploration fleet ] {fl['walkers']} walkers, "
              f"{fl['steps']} fused steps, {fl['restarts']} restarts, "
              f"{fl['nan_resets']} nan resets")
    if args.oracle_budget > 0:
        ctrl = rep.get("budget_controller", {})
        print(f"[budget controller ] realized rate="
              f"{rep.get('oracle_rate') or 0:.3f} "
              f"(target {args.oracle_budget}), "
              f"effective threshold={ctrl.get('threshold', 0):.4f} "
              f"(seed 0.3), rounds={int(ctrl.get('rounds', 0))}")

    cparams_rnd = run_random_baseline(labeled or args.budget)
    mae_rnd = force_mae(cparams_rnd, coords_test, forces_test)
    print(f"[random baseline   ] labeled={labeled} "
          f"force MAE={mae_rnd:.4f}")
    print(f"AL improvement: {mae_rnd / max(mae_al, 1e-9):.2f}x lower MAE")
    print(f"exchange iterations: "
          f"{rep['counters'].get('exchange.iterations')}, "
          f"retrains: {rep['counters'].get('train.retrains')}")


if __name__ == "__main__":
    main()
