"""PAL at LM scale: uncertainty-driven data selection for LM training
(DESIGN.md §3 — the datacenter path the dry-run/roofline exercises).

The five kernels instantiated with transformers:
  generator  = prompt sampler proposing candidate sequences
  prediction = a committee of K small LMs; disagreement = std over members
               of sequence mean-NLL (core/committee.lm_committee_uncertainty)
  oracle     = a larger 'teacher' LM that labels sequences (next-token
               targets = teacher greedy continuations) — the stand-in for
               expensive ground truth, exactly the paper's oracle role
  training   = the SHARED fused committee trainer (training/
               committee_trainer.py): every student advances in one
               vmapped dispatch per step on teacher-labeled sequences
               from the device replay ring
  controller = the same Exchange/Manager machinery as the MD example

Prediction runs on the unified acquisition engine: the student committee is
a ``CommitteeSpec`` (stacked params, vmapped seq-NLL forward) and selection
is a CUSTOM rule pipeline — threshold + top-fraction cap on teacher traffic
— compiled INTO the fused dispatch, so custom selection still costs one
device round trip per exchange iteration.

  PYTHONPATH=src python examples/lm_active_distill.py
"""
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig
from repro.configs.pal_potential import PALRunConfig
from repro.core import (CommitteeSpec, PAL, ThresholdRule, TopFractionRule,
                        UserGene, UserOracle)
from repro.core import committee as cmte
from repro.models.model_zoo import build_model
from repro.models.transformer import lm_loss

SEQ = 32
VOCAB = 512

STUDENT = ModelConfig(
    name="student", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=VOCAB, dtype="float32",
    param_dtype="float32", remat="none")
TEACHER = ModelConfig(
    name="teacher", family="dense", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=4, d_ff=256, vocab_size=VOCAB, dtype="float32",
    param_dtype="float32", remat="none")


class PromptGene(UserGene):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, data_to_gene):
        # structured prompts: arithmetic-ish token patterns in a band
        start = self.rng.randint(0, VOCAB - SEQ)
        stride = self.rng.randint(1, 5)
        seq = (start + stride * np.arange(SEQ)) % VOCAB
        return False, seq.astype(np.float32)   # transport is float 1-D


_STUDENT_MODEL = build_model(STUDENT)


def student_loss(p, batch):
    """ONE student's distillation loss for the fused committee trainer:
    next-token cross entropy on the teacher-labeled sequence (``batch["y"]``
    is the oracle output — prompt head + teacher continuation — shipped as
    float over the paper's 1-D transport and cast back here)."""
    toks = batch["y"].astype(jnp.int32)
    logits = _STUDENT_MODEL.forward(p, {"tokens": toks[:, :-1]})
    return lm_loss(logits, toks[:, 1:])[0], {}


class TeacherOracle(UserOracle):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.model = build_model(TEACHER)
        self.params = self.model.init(jax.random.PRNGKey(42))  # shared teacher
        fwd = self.model.forward

        def relabel(p, tokens):
            logits = fwd(p, {"tokens": tokens})
            return jnp.argmax(logits, axis=-1)      # teacher next-token map

        self._relabel = jax.jit(relabel)

    def run_calc(self, inp):
        toks = jnp.asarray(inp.astype(np.int32))[None]
        teacher_next = np.asarray(self._relabel(self.params, toks))[0]
        # labeled sequence: prompt token followed by teacher continuation
        labeled = np.concatenate([inp[:1].astype(np.int32),
                                  teacher_next.astype(np.int32)])
        return inp, labeled.astype(np.float32)


def make_student_committee(n_members: int) -> CommitteeSpec:
    """Stacked student committee for the fused engine: one member's params
    mapped over a float token batch -> per-sequence mean NLL (n, 1)."""
    model = build_model(STUDENT)
    fwd = model.forward

    def member_nll(p, x):                        # (n, SEQ) float -> (n, 1)
        toks = x.astype(jnp.int32)
        logits = fwd(p, {"tokens": toks[:, :-1]})
        return jnp.mean(cmte.lm_token_nll(logits, toks[:, 1:]),
                        axis=-1, keepdims=True)

    cparams = cmte.stack_members(
        [model.init(jax.random.PRNGKey(i)) for i in range(n_members)])
    return CommitteeSpec(member_nll, cparams)


def main():
    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(prefix="pal_lm_"),
        gene_process=8, orcl_process=2, pred_process=3, ml_process=3,
        retrain_size=24, std_threshold=0.08, patience=1000,
        weight_sync_every=1,
        train_steps=30, train_batch=16, train_lr=1e-3,
        train_replay_capacity=512)
    # custom selection compiled into the fused dispatch: disagreement
    # threshold, then cap teacher traffic at the 50% most-uncertain
    rules = (ThresholdRule(cfg.std_threshold), TopFractionRule(0.5))
    pal = PAL(cfg, make_generator=PromptGene, make_oracle=TeacherOracle,
              committee=make_student_committee(cfg.pred_process),
              loss_fn=student_loss, rules=rules)
    pal.start()
    t0 = time.time()
    while pal.train_buffer.total_labeled < 120 and time.time() - t0 < 120:
        time.sleep(0.25)
    pal.shutdown()
    rep = pal.report()
    print(f"labeled sequences   : {rep['labeled_total']}")
    print(f"exchange iterations : "
          f"{rep['counters'].get('exchange.iterations')}")
    print(f"retrains            : {rep['counters'].get('train.retrains')}")
    print(f"fused train steps   : {rep['train_fused_steps']}")
    print(f"device weight hands : {rep['device_weight_refreshes']}")
    sel_frac = rep["labeled_total"] / max(
        rep["counters"].get("exchange.iterations", 1) * cfg.gene_process, 1)
    print(f"selection fraction  : {sel_frac:.3f} "
          f"(uncertainty filter at work — only disagreed-on sequences "
          f"hit the teacher)")
    assert rep["labeled_total"] > 0
    print("OK")


if __name__ == "__main__":
    main()
